"""LM substrate numerics: flash attention, recurrent cores, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention, layers, rglru, xlstm
from repro.models.config import ModelConfig

RNG = np.random.default_rng(0)


def _ref_attn(q, k, v, window=0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    k = jnp.repeat(k, H // K, axis=2)
    v = jnp.repeat(v, H // K, axis=2)
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) / np.sqrt(hd)
    i = jnp.arange(S)
    mask = i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > i[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqt,bthd->bqhd", jax.nn.softmax(s, -1), v)


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("K", [2, 4])
def test_flash_forward_and_grads(window, K):
    B, S, H, hd = 2, 64, 4, 16
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = attention.flash_attention(q, k, v, pos, pos, window=window,
                                    q_chunk=16, kv_chunk=16)
    ref = _ref_attn(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    f1 = lambda *a: (attention.flash_attention(*a, pos, pos, window=window,
                                               q_chunk=16, kv_chunk=16) ** 2).sum()
    f2 = lambda *a: (_ref_attn(*a, window) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_decode_matches_forward_gqa():
    """Token-by-token decode through the KV cache == full forward."""
    cfg = ModelConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab_size=64, dtype="float32")
    params = attention.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 12
    x = jnp.asarray(RNG.normal(size=(B, S, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = attention.attention_block(cfg, params, x, pos, kind="attn")

    cache = attention.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = attention.attention_block(
            cfg, params, x[:, t : t + 1], pos[:, t : t + 1], kind="attn",
            cache=cache,
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=1e-4)


def test_ring_cache_local_attention_decode():
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab_size=64, window=6, dtype="float32")
    params = attention.init_attention(jax.random.PRNGKey(1), cfg, jnp.float32)
    B, S = 1, 16
    x = jnp.asarray(RNG.normal(size=(B, S, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = attention.attention_block(cfg, params, x, pos, kind="local")

    ring = min(S, cfg.window)
    cache = attention.init_cache(cfg, B, ring, jnp.float32)
    cache["kv_pos"] = jnp.full((B, ring), -1, jnp.int32)
    outs = []
    for t in range(S):
        o, cache = attention.attention_block(
            cfg, params, x[:, t : t + 1], pos[:, t : t + 1], kind="local",
            cache=cache,
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=1e-4)


def test_rglru_scan_matches_stepwise():
    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
                      d_ff=32, vocab_size=8, rnn_width=16, dtype="float32")
    params = rglru.init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jnp.asarray(RNG.normal(size=(B, S, 16)), jnp.float32)
    full, _ = rglru.rglru_block(cfg, params, x)

    cache = rglru.init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = rglru.rglru_block(cfg, params, x[:, t : t + 1], cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=1e-4)


def test_mlstm_chunked_matches_stepwise():
    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_ff=0, vocab_size=8, dtype="float32")
    params = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 16
    x = jnp.asarray(RNG.normal(size=(B, S, 16)) * 0.3, jnp.float32)
    full, _ = xlstm.mlstm_block(cfg, params, x)

    cache = xlstm.init_mlstm_cache(cfg, B)
    outs = []
    for t in range(S):
        o, cache = xlstm.mlstm_block(cfg, params, x[:, t : t + 1], cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-4)


def test_slstm_chunking_invariance():
    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_ff=0, vocab_size=8, dtype="float32")
    params = xlstm.init_slstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 16
    x = jnp.asarray(RNG.normal(size=(B, S, 16)), jnp.float32)
    st = xlstm.init_slstm_state(cfg, B)
    out1, _ = xlstm._slstm_scan(cfg, params, x, st, chunk=4)
    st = xlstm.init_slstm_state(cfg, B)
    out2, _ = xlstm._slstm_scan(cfg, params, x, st, chunk=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_chunked_ce_matches_direct():
    B, S, d, V = 2, 16, 8, 32
    x = jnp.asarray(RNG.normal(size=(B, S, d)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(d, V)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
    labels = labels.at[0, :3].set(-1)  # masked positions
    chunked = layers.chunked_ce_loss(x, w, labels, n_chunks=4)
    logits = (x @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    direct = ((lse - tgt) * mask).sum() / mask.sum()
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-6)


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on relative position."""
    hd = 16
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)
    def score(off):
        qp = jnp.asarray([[5 + off]], jnp.int32)
        kp = jnp.asarray([[2 + off]], jnp.int32)
        qr = layers.apply_rope(q, qp, 10000.0)
        kr = layers.apply_rope(k, kp, 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(score(0) - score(37)) < 1e-4


def test_rglru_prefill_then_decode_matches_full():
    """Prefill-through-cache + decode == full-sequence forward (the path the
    prefill_32k dry-run cells exercise for recurrent archs)."""
    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
                      d_ff=32, vocab_size=8, rnn_width=16, dtype="float32")
    params = rglru.init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 12
    x = jnp.asarray(RNG.normal(size=(B, S, 16)), jnp.float32)
    full, _ = rglru.rglru_block(cfg, params, x)

    cache = rglru.init_rglru_cache(cfg, B, jnp.float32)
    pre, cache = rglru.rglru_block(cfg, params, x[:, :8], cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, :8]), np.asarray(pre), atol=1e-4)
    outs = [pre]
    for t in range(8, S):
        o, cache = rglru.rglru_block(cfg, params, x[:, t : t + 1], cache=cache)
        outs.append(o)
    joined = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(joined), atol=1e-4)


def test_mlstm_prefill_then_decode_matches_full():
    cfg = ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_ff=0, vocab_size=8, dtype="float32")
    params = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 16
    x = jnp.asarray(RNG.normal(size=(B, S, 16)) * 0.3, jnp.float32)
    full, _ = xlstm.mlstm_block(cfg, params, x)

    cache = xlstm.init_mlstm_cache(cfg, B)
    pre, cache = xlstm.mlstm_block(cfg, params, x[:, :12], cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, :12]), np.asarray(pre), atol=2e-4)
    outs = [pre]
    for t in range(12, S):
        o, cache = xlstm.mlstm_block(cfg, params, x[:, t : t + 1], cache=cache)
        outs.append(o)
    joined = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(joined), atol=2e-4)
