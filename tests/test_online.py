"""Online-learning service: drift math, incremental recompile exactness,
feedback hygiene, rebuild/swap fault drills, the shadow-canary verdict,
post-swap rollback, the SIGTERM feedback drain, and the end-to-end
serve -> feedback -> drift -> recompile -> canary -> atomic-swap
acceptance drill through the gateway.
"""

import asyncio

import numpy as np
import pytest

from repro.core import compiler, packetizer, tm
from repro.runtime import faults
from repro.runtime.online import (
    CANARY, IDLE, FeedbackQueue, OnlineConfig, OnlineUpdater,
)
from repro.runtime.zoo import OPEN, ArtifactZoo, TenantQuarantined

pytestmark = pytest.mark.online

CFG = tm.TMConfig(n_features=16, n_classes=3, clauses_per_class=4,
                  threshold=8, s=4.0)


def _bank(seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-60, 20, size=(CFG.n_clauses_raw, CFG.n_literals),
                        ).astype(np.int8)


def _data(n, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(n, CFG.n_features)).astype(np.uint8)
    y = rng.integers(0, CFG.n_classes, size=n).astype(np.int32)
    return X, y


def _pack(X):
    lits = np.concatenate([X, 1 - X], axis=1).astype(np.uint8)
    return packetizer.pack_bits_np(lits)


def _sched_equal(a, b):
    for f in ("block_c", "block_j", "n_rows", "n_lit_bits"):
        assert getattr(a, f) == getattr(b, f), f
    for f in ("chain_ids", "tile_cb", "tile_jb", "tile_first", "tile_last",
              "counts", "indptr"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


# -- drift math ---------------------------------------------------------------

def test_include_drift_counts_flipped_bits():
    ta = np.full((CFG.n_clauses_raw, CFG.n_literals), -10, np.int8)
    ta[:, 0] = 10                      # every clause includes literal 0
    ref = compiler.dense_include_words(CFG, ta)
    live = ta.copy()
    live[0, 1] = 10                    # one new include ...
    live[1, 0] = -10                   # ... one dropped include
    d = compiler.include_drift(ref, compiler.dense_include_words(CFG, live))
    assert d.n_bits_changed == 2 and d.n_clauses_changed == 2
    assert d.n_includes_ref == CFG.n_clauses_raw
    assert d.n_includes_live == CFG.n_clauses_raw
    assert d.drift == pytest.approx(2 / CFG.n_clauses_raw)
    assert d.as_dict()["drift"] == d.drift
    # an unchanged bank reads exactly 0.0
    assert compiler.include_drift(ref, ref).drift == 0.0


def test_include_drift_shape_mismatch_is_loud():
    ta = _bank()
    ref = compiler.dense_include_words(CFG, ta)
    with pytest.raises(ValueError):
        compiler.include_drift(ref[:1], ref)


# -- incremental recompile ----------------------------------------------------

def test_incremental_recompile_bit_exact_vs_full():
    ta = _bank()
    prev = compiler.compile_tm(CFG, ta)
    prev.schedule()                    # materialize the default tiling
    prev.tuned["sparse_infer:B64"] = {"block_c": 8}
    live = ta.copy()
    # guaranteed include-bit flips on two clauses (int8-safe)
    live[3, :4] = np.where(live[3, :4] >= 0, -50, 50)
    live[7, 2:5] = np.where(live[7, 2:5] >= 0, -50, 50)
    new, info = compiler.incremental_recompile(CFG, live, prev)
    ref = compiler.compile_tm(CFG, live)
    assert np.array_equal(new.include_words, ref.include_words)
    assert np.array_equal(new.word_ids, ref.word_ids)
    assert np.array_equal(new.votes, ref.votes)
    if info["mode"] == "incremental":
        # the reused-rows schedule must be EXACTLY the from-scratch one
        _sched_equal(new.schedule(), ref.schedule())
        # tuned tilings carry over to the same-layout successor
        assert new.tuned["sparse_infer:B64"] == {"block_c": 8}
    else:
        assert info == dict(mode="full", rows_reused=0, tiles_reused=0)
    # either way predictions are identical
    xw = _pack(_data(8)[0])
    a = np.asarray(compiler.run_compiled(new, xw, engine="oracle"))
    b = np.asarray(compiler.run_compiled(ref, xw, engine="oracle"))
    assert np.array_equal(a, b)


def test_incremental_recompile_falls_back_on_layout_change():
    ta = _bank()
    prev = compiler.compile_tm(CFG, ta)
    prev.schedule()
    live = ta.copy()
    live[:, :] = np.abs(live)          # everything includes: layout changes
    new, info = compiler.incremental_recompile(CFG, live, prev)
    assert info["mode"] == "full"
    ref = compiler.compile_tm(CFG, live)
    assert np.array_equal(new.include_words, ref.include_words)


def test_build_schedule_incremental_reuses_clean_tiles():
    from repro.kernels import sparse_infer

    rng = np.random.default_rng(1)
    iw = rng.integers(0, 2**32, size=(24, 2), dtype=np.uint32)
    iw[iw.sum(axis=1) == 0, 0] = 1     # keep every row nonempty
    prev = sparse_infer.build_schedule(iw, block_c=8, block_j=8)
    live = iw.copy()
    live[20] ^= 0b1011                 # touch only the LAST clause block
    sched, info = sparse_infer.build_schedule_incremental(
        live, prev, iw, block_c=8, block_j=8)
    ref = sparse_infer.build_schedule(live, block_c=8, block_j=8)
    _sched_equal(sched, ref)
    assert info["rows_reused"] == 23 and info["rows_rebuilt"] == 1
    # blocks 0 and 1 were untouched: their tiles count as reused
    assert info["tiles_reused"] >= int(prev.counts[:2].sum()) > 0


def test_build_schedule_incremental_falls_back_on_shape_change():
    from repro.kernels import sparse_infer

    rng = np.random.default_rng(2)
    iw = rng.integers(1, 2**32, size=(16, 2), dtype=np.uint32)
    prev = sparse_infer.build_schedule(iw, block_c=8, block_j=8)
    live = rng.integers(1, 2**32, size=(24, 2), dtype=np.uint32)
    sched, info = sparse_infer.build_schedule_incremental(
        live, prev, iw, block_c=8, block_j=8)
    assert info["rows_reused"] == 0 and info["tiles_reused"] == 0
    _sched_equal(sched, sparse_infer.build_schedule(live, block_c=8,
                                                    block_j=8))


# -- feedback hygiene ---------------------------------------------------------

def test_feedback_queue_overflow_is_counted():
    q = FeedbackQueue(max_pending=2)
    x = np.zeros(4, np.uint8)
    assert q.put(x, 0) and q.put(x, 1)
    assert not q.put(x, 2)             # typed drop, never silent
    assert q.dropped_overflow == 1 and q.accepted == 2 and len(q) == 2
    assert q.pop_batch(3) is None      # partial batches stay queued
    xb, yb = q.pop_batch(2)
    assert xb.shape == (2, 4) and list(yb) == [0, 1]


def test_feedback_corrupt_drill_rejected_never_trained():
    ta = _bank()
    upd = OnlineUpdater(CFG, ta, compiler.compile_tm(CFG, ta),
                        cfg=OnlineConfig(batch_size=4, drift_threshold=10.0))
    X, y = _data(8)
    with faults.injected("online.feedback_corrupt*1"):
        assert not upd.ingest(X[0], y[0])    # corrupted BEFORE validation
    assert upd.rejected_corrupt == 1 and len(upd.queue) == 0
    assert not upd.ingest(np.zeros(3, np.uint8), 0)      # bad shape
    assert not upd.ingest(X[0], CFG.n_classes)           # label range
    assert upd.rejected_corrupt == 3 and upd.ingested == 0
    for i in range(4):
        assert upd.ingest(X[i], y[i])
    assert upd.step()                   # the clean batch trains
    assert upd.steps == 1 and upd.gstep == 1


# -- rebuild + swap fault drills ----------------------------------------------

def _mk_updater(ta, compiled, **cfg_kw):
    """Updater over a real zoo with the entry primed at version 1."""
    current = {"compiled": compiled}

    def make_obj(c):
        return {"compiled": c}, 1

    zoo = ArtifactZoo(lambda t: make_obj(current["compiled"]))
    with zoo.lease("t0"):
        pass
    cfg = OnlineConfig(**{**dict(drift_threshold=0.0, batch_size=4,
                                 swap_policy="immediate"), **cfg_kw})
    upd = OnlineUpdater(CFG, ta, compiled, cfg=cfg, zoo=zoo, tenant="t0",
                        make_obj=make_obj,
                        deployed_obj={"compiled": compiled},
                        deployed_nbytes=1)
    return upd, zoo


def _feed_and_step(upd, seed):
    X, y = _data(upd.cfg.batch_size, seed=seed)
    for i in range(upd.cfg.batch_size):
        upd.ingest(X[i], y[i])
    assert upd.step()


def test_rebuild_fail_drill_keeps_serving_then_retries():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    upd, zoo = _mk_updater(ta, compiled)
    with faults.injected("online.rebuild_fail*1"):
        _feed_and_step(upd, seed=1)
    assert upd.rebuild_failures == 1 and upd.rebuilds == 0
    assert upd.promotions == 0
    assert upd.deployed is compiled     # the deployed artifact never moved
    assert zoo.version("t0") == 1
    _feed_and_step(upd, seed=2)         # next drift check retries
    assert upd.rebuilds == 1 and upd.promotions == 1
    assert zoo.version("t0") == 2


def test_swap_abort_drill_never_half_promotes():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    orig_words = compiled.include_words.copy()
    upd, zoo = _mk_updater(ta, compiled)
    with faults.injected("zoo.swap_abort@0*1"):     # tenant t0 -> step 0
        _feed_and_step(upd, seed=1)
    assert upd.swap_aborts == 1 and upd.promotions == 0
    assert zoo.version("t0") == 1                   # commit never happened
    with zoo.lease("t0") as obj:
        assert obj["compiled"] is compiled          # old object ...
        assert np.array_equal(obj["compiled"].include_words, orig_words)
    assert upd.state == IDLE                        # candidate discarded
    _feed_and_step(upd, seed=2)                     # retry promotes cleanly
    assert upd.promotions == 1 and zoo.version("t0") == 2


# -- shadow canary ------------------------------------------------------------

def test_failed_canary_discards_candidate_and_trips_breaker():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    upd, zoo = _mk_updater(
        ta, compiled, swap_policy="canary", canary_min=1, canary_frac=1.0)
    # candidate side always disagrees with the serving predictions
    upd.serve_fn = lambda obj, rows: np.full(len(rows), 0, np.int64)
    _feed_and_step(upd, seed=1)
    assert upd.state == CANARY
    rows = list(_pack(_data(4, seed=9)[0]))
    upd.mirror("t0", rows, np.full(len(rows), 1, np.int64))
    assert upd.canary_failures == 1 and upd.promotions == 0
    assert upd.state == IDLE and upd._candidate is None
    assert zoo.version("t0") == 1                   # never swapped
    assert zoo.breakers["t0"].state == OPEN         # breaker tripped
    assert upd.deployed is compiled


def test_canary_pass_promotes_and_mirror_ignores_other_tenants():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    upd, zoo = _mk_updater(
        ta, compiled, swap_policy="canary", canary_min=2, canary_frac=1.0)
    _feed_and_step(upd, seed=1)
    assert upd.state == CANARY
    xw = _pack(_data(4, seed=9)[0])
    agreeing = np.asarray(upd.serve_fn(upd._cand_obj, list(xw)))
    upd.mirror("t9", list(xw), np.zeros(4, np.int64))   # wrong tenant
    assert upd._canary_buckets == 0
    upd.mirror("t0", list(xw), agreeing)
    assert upd.state == CANARY                     # canary_min not reached
    upd.mirror("t0", list(xw), agreeing)
    assert upd.promotions == 1 and upd.canary_passes == 1
    assert zoo.version("t0") == 2


# -- post-swap rollback -------------------------------------------------------

def test_post_swap_regression_rolls_back_bit_exact():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    orig_words = compiled.include_words.copy()
    upd, zoo = _mk_updater(ta, compiled, regression_window=2,
                           regression_drop=0.2)

    def feed_labeled(seed, truthful):
        X, _ = _data(upd.cfg.batch_size, seed=seed)
        preds = np.argmax(np.asarray(compiler.run_compiled(
            upd.deployed, _pack(X))), axis=-1)
        ys = preds if truthful else (preds + 1) % CFG.n_classes
        for i in range(upd.cfg.batch_size):
            upd.ingest(X[i], int(ys[i]))
        assert upd.step()

    feed_labeled(1, truthful=True)      # acc 1.0 window -> promote
    assert upd.promotions == 1 and zoo.version("t0") == 2
    upd.cfg.drift_threshold = 10.0      # freeze promotions; watch only
    feed_labeled(2, truthful=False)     # deployed acc collapses to 0.0
    feed_labeled(3, truthful=False)
    assert len(upd.rollbacks) == 1
    assert "accuracy regression" in upd.rollbacks[0]["reason"]
    # the RETAINED pre-swap artifact is back, bit-exact, and the breaker
    # is open so the regressed tenant cools down
    assert upd.deployed is compiled
    assert np.array_equal(upd.deployed.include_words, orig_words)
    assert zoo.version("t0") == 3       # swap-back is itself an atomic swap
    with pytest.raises(TenantQuarantined):
        with zoo.lease("t0"):
            pass


def test_post_swap_rollback_is_idempotent():
    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    upd, zoo = _mk_updater(ta, compiled)
    _feed_and_step(upd, seed=1)
    assert upd.promotions == 1
    upd.rollback("manual")
    n = zoo.health()["swaps"]
    upd.rollback("again")               # no retained previous: no-op
    assert len(upd.rollbacks) == 1 and zoo.health()["swaps"] == n


# -- drain / resume -----------------------------------------------------------

def test_drain_checkpoints_pending_feedback_and_resume_reingests(tmp_path):
    from repro.checkpoint.store import CheckpointManager

    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    upd = OnlineUpdater(CFG, ta, compiled,
                        cfg=OnlineConfig(batch_size=4, drift_threshold=10.0),
                        ckpt_manager=CheckpointManager(str(tmp_path)))
    X, y = _data(6, seed=3)
    for i in range(4):
        upd.ingest(X[i], y[i])
    assert upd.step()
    for i in range(4, 6):               # partial batch stays pending
        upd.ingest(X[i], y[i])
    assert upd.drain() == 1 and len(upd.queue) == 0

    # a restarted updater resumes the bank AND re-ingests the drained
    # feedback — SIGTERM lost nothing
    upd2 = OnlineUpdater(CFG, _bank(seed=99), compiled,
                         cfg=OnlineConfig(batch_size=4,
                                          drift_threshold=10.0),
                         ckpt_manager=CheckpointManager(str(tmp_path)))
    assert upd2.gstep == 1 and len(upd2.queue) == 2
    assert np.array_equal(upd2._ta, upd._ta)       # bank bit-exact
    for i in range(2):                  # top up to a full batch: it trains
        upd2.ingest(X[i], y[i])
    assert upd2.step() and upd2.gstep == 2


# -- end to end through the gateway -------------------------------------------

def test_end_to_end_drift_canary_swap_through_gateway():
    """The acceptance drill: serve under load -> labeled feedback -> drift
    crossing -> recompile -> shadow canary on mirrored buckets -> atomic
    swap — with ``offered == answered + shed`` intact and every bucket
    answered by a fully-committed artifact (never a half-promoted one)."""
    from repro.runtime.gateway import Gateway

    ta = _bank()
    compiled = compiler.compile_tm(CFG, ta)
    compiled.schedule()                 # give the incremental path its shot
    current = {"compiled": compiled}
    served_ids = []

    def serve_rows(obj, rows):
        served_ids.append(id(obj["compiled"]))
        xw = np.stack([np.asarray(r) for r in rows])
        return np.argmax(np.asarray(compiler.run_compiled(
            obj["compiled"], xw, engine="oracle")), axis=-1)

    def make_obj(c):
        return {"compiled": c}, 1

    zoo = ArtifactZoo(lambda t: make_obj(current["compiled"]))
    runner = zoo.runner(serve_rows)
    upd = OnlineUpdater(
        CFG, ta, compiled,
        cfg=OnlineConfig(drift_threshold=0.0, batch_size=4,
                         swap_policy="canary", canary_min=2,
                         canary_frac=1.0, canary_agreement=0.0),
        zoo=zoo, tenant="t0", make_obj=make_obj, serve_fn=serve_rows,
        deployed_obj={"compiled": compiled}, deployed_nbytes=1)

    X, y = _data(32, seed=5)
    xw = _pack(X)

    async def go():
        gw = await Gateway(runner, bucket=4, max_wait=0.01,
                           mirror=upd.mirror).start()

        async def offer(lo, hi):
            futs = [gw.offer("t0", xw[j]) for j in range(lo, hi)]
            return await asyncio.gather(*futs)

        r1 = await offer(0, 8)                      # version 1 serves
        for i in range(4):                          # feedback -> drift
            upd.ingest(X[i], int(y[i]))
        assert upd.step() and upd.state == CANARY
        assert upd.rebuilds == 1
        r2 = await offer(8, 24)       # mirrored buckets decide the canary
        r3 = await offer(24, 32)
        h = await gw.drain()
        return r1 + r2 + r3, h

    res, h = asyncio.run(go())
    assert upd.canary_passes == 1 and upd.promotions == 1
    assert zoo.version("t0") == 2
    assert h["unaccounted"] == 0 and h["answered"] == 32
    assert h["mirrored"] >= 2 and h["mirror_failures"] == 0
    assert all(r.ok for r in res)
    # every bucket was served by a committed artifact: the original or the
    # promoted candidate — nothing in between
    assert set(served_ids) <= {id(compiled), id(upd.deployed)}
    assert id(compiled) in served_ids and id(upd.deployed) in served_ids
