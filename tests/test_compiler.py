"""Boolean-to-silicon compiler: equivalence + compaction properties.

``hypothesis`` is optional: when installed, the two central properties run
as real property tests; otherwise fixed-seed parametrized fallbacks keep
the same checks in the tier-1 suite.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 container has no hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import compiler, packetizer, tm


def _random_tm(n_features, n_classes, cpc, include_density, seed):
    rng = np.random.default_rng(seed)
    C = n_classes * cpc
    ta = np.where(
        rng.random((C, 2 * n_features)) < include_density,
        rng.integers(0, 127, (C, 2 * n_features)),
        rng.integers(-128, 0, (C, 2 * n_features)),
    ).astype(np.int8)
    cfg = tm.TMConfig(n_features=n_features, n_classes=n_classes, clauses_per_class=cpc)
    return cfg, ta


def _check_compiled_equals_dense(n_features, n_classes, cpc, density, seed):
    """The central correctness property: the compacted artifact classifies
    identically to dense inference, for any automata state."""
    cfg, ta = _random_tm(n_features, n_classes, cpc, density, seed)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(
        np.random.default_rng(seed + 1).integers(0, 2, (16, n_features), dtype=np.uint8)
    )
    state = tm.TMState(ta_state=jnp.asarray(ta), steps=jnp.int32(0))
    dense_sums = tm.class_sums(cfg, state.ta_state, tm.literals(x), training=False)
    comp_sums = compiler.run_compiled(comp, packetizer.pack_literals(x))
    np.testing.assert_array_equal(np.asarray(dense_sums), np.asarray(comp_sums))


def _check_dont_touch_equals_optimized(seed):
    """Fig. 8 analog: disabling the optimizations changes resources, never
    results."""
    cfg, ta = _random_tm(40, 3, 8, 0.1, seed)
    x = jnp.asarray(np.random.default_rng(seed).integers(0, 2, (8, 40), dtype=np.uint8))
    xp = packetizer.pack_literals(x)
    opt = compiler.compile_tm(cfg, ta)
    dt = compiler.compile_tm(cfg, ta, dedup=False, prune_words=False)
    np.testing.assert_array_equal(
        np.asarray(compiler.run_compiled(opt, xp)),
        np.asarray(compiler.run_compiled(dt, xp)),
    )
    assert opt.n_unique <= dt.n_unique
    assert opt.n_words_active <= dt.n_words_active


if HAVE_HYPOTHESIS:
    @pytest.mark.hypothesis_optional
    @settings(max_examples=20, deadline=None)
    @given(
        n_features=st.integers(3, 80),
        n_classes=st.integers(2, 5),
        cpc=st.integers(2, 12),
        density=st.floats(0.0, 0.3),
        seed=st.integers(0, 10_000),
    )
    def test_compiled_equals_dense(n_features, n_classes, cpc, density, seed):
        _check_compiled_equals_dense(n_features, n_classes, cpc, density, seed)

    @pytest.mark.hypothesis_optional
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_dont_touch_equals_optimized(seed):
        _check_dont_touch_equals_optimized(seed)


@pytest.mark.parametrize(
    "n_features,n_classes,cpc,density,seed",
    [
        (3, 2, 2, 0.0, 0),         # tiny + all-empty bank
        (17, 3, 5, 0.05, 11),      # sparse ragged
        (80, 5, 12, 0.3, 4242),    # dense upper corner
        (33, 2, 7, 0.15, 977),
    ],
)
def test_compiled_equals_dense_fixed(n_features, n_classes, cpc, density, seed):
    """Fixed-seed fallback for the central property (always runs)."""
    _check_compiled_equals_dense(n_features, n_classes, cpc, density, seed)


@pytest.mark.parametrize("seed", [0, 7, 123])
def test_dont_touch_equals_optimized_fixed(seed):
    """Fixed-seed fallback for the Fig. 8 property (always runs)."""
    _check_dont_touch_equals_optimized(seed)


def test_stats_invariants():
    cfg, ta = _random_tm(60, 4, 10, 0.05, 0)
    comp = compiler.compile_tm(cfg, ta)
    s = comp.stats
    assert s.n_clauses_unique <= s.n_clauses_nonempty <= s.n_clauses_dense
    assert s.n_words_active <= s.n_words_dense
    assert 0.0 <= s.include_sparsity <= 1.0
    assert comp.votes.shape == (comp.n_unique, cfg.n_classes)


def test_vote_folding_counts_multiplicity():
    """Two identical clauses with + polarity in the same class => vote 2."""
    cfg = tm.TMConfig(n_features=4, n_classes=1, clauses_per_class=3)
    ta = np.full((3, 8), -1, np.int8)
    ta[0, 0] = 1   # clause 0 (+): include literal 0
    ta[2, 0] = 1   # clause 2 (+): identical
    ta[1, 1] = 1   # clause 1 (-): include literal 1
    comp = compiler.compile_tm(cfg, ta)
    assert comp.n_unique == 2
    assert sorted(comp.votes[:, 0].tolist()) == [-1, 2]


def test_empty_model_compiles():
    cfg = tm.TMConfig(n_features=8, n_classes=2, clauses_per_class=2)
    ta = np.full((4, 16), -5, np.int8)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.zeros((3, 8), np.uint8))
    sums = compiler.run_compiled(comp, packetizer.pack_literals(x))
    np.testing.assert_array_equal(np.asarray(sums), 0)


def test_save_load_roundtrip():
    cfg, ta = _random_tm(30, 3, 6, 0.1, 7)
    comp = compiler.compile_tm(cfg, ta)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "model.npz")
        comp.save(path)
        back = compiler.CompiledTM.load(path)
    np.testing.assert_array_equal(comp.include_words, back.include_words)
    np.testing.assert_array_equal(comp.votes, back.votes)
    np.testing.assert_array_equal(comp.word_ids, back.word_ids)
    assert back.stats.n_clauses_dense == comp.stats.n_clauses_dense


def test_kernel_path_equivalence():
    """oracle == fused single-pass kernel == unfused two-kernel pipeline."""
    cfg, ta = _random_tm(100, 4, 16, 0.08, 3)
    comp = compiler.compile_tm(cfg, ta)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 2, (12, 100), dtype=np.uint8))
    a = compiler.predict_compiled(comp, x, engine="oracle")
    b = compiler.predict_compiled(
        comp, x, engine=compiler.EngineSpec(use_kernel=True), interpret=True)
    c = compiler.predict_compiled(
        comp, x, engine=compiler.EngineSpec(use_kernel=True, fuse=False),
        interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_run_compiled_dispatch_defaults():
    """run_compiled defers to ops._resolve: off-TPU defaults to the oracle
    path with interpret resolved (no unconditional interpret=True), and
    explicit kernel dispatch matches it bit-for-bit."""
    from repro.kernels import ops

    cfg, ta = _random_tm(24, 3, 6, 0.12, 9)
    comp = compiler.compile_tm(cfg, ta)
    xp = packetizer.pack_literals(
        jnp.asarray(np.random.default_rng(1).integers(0, 2, (9, 24), dtype=np.uint8))
    )
    uk, it = ops.kernel_dispatch()
    default = compiler.run_compiled(comp, xp)
    explicit = compiler.run_compiled(
        comp, xp, engine=compiler.EngineSpec(use_kernel=uk), interpret=it)
    kernel = compiler.run_compiled(
        comp, xp, engine=compiler.EngineSpec(use_kernel=True), interpret=True)
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    np.testing.assert_array_equal(np.asarray(default), np.asarray(kernel))
