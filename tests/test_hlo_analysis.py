"""The roofline's HLO analyzer: validated against known-FLOP programs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scanned_matmul_trip_counts():
    """XLA's cost_analysis counts while bodies once; ours resolves trips."""
    def step(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), 0
        x, _ = jax.lax.scan(body, x, w)
        return x

    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    txt = _compile(step, w, x)
    cost = H.analyze(txt)
    expected = 10 * 2 * 32 * 128 * 128
    assert 0.95 < cost.flops / expected < 1.10, cost.flops / expected


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    txt = _compile(lambda a, b: a @ b, a, b)
    cost = H.analyze(txt)
    expected = 2 * 64 * 256 * 32
    assert 0.95 < cost.flops / expected < 1.05


def test_bytes_reasonable_for_elementwise():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    txt = _compile(lambda x: x * 2 + 1, x)
    cost = H.analyze(txt)
    # read + write = 8 MB; fusions should keep us within 2x of that
    assert 8e6 <= cost.bytes <= 2.5e7, cost.bytes


def test_shape_parsing():
    s = H.parse_shape("bf16[128,4096]{1,0}")
    assert s.dtype == "bf16" and s.dims == (128, 4096)
    assert s.n_bytes == 128 * 4096 * 2
    t = H.parse_shape("(s32[], f32[8,8]{1,0})")
    assert t.tuple_elems is not None and t.n_bytes == 4 + 256 + 0


def test_collective_wire_model():
    op = H.Op("ag", H.parse_shape("f32[64,256]"), "all-gather", ["x"],
              "replica_groups=[2,4]<=[8], dimensions={1}")
    comp = H.Computation("c", {}, [])
    wire = H._collective_wire_bytes(op, comp)
    assert wire == 64 * 256 * 4 * 3 / 4  # (g-1)/g of the gathered result

    ar = H.Op("ar", H.parse_shape("f32[1024]"), "all-reduce", ["x"],
              "replica_groups=[1,8]<=[8]")
    assert H._collective_wire_bytes(ar, comp) == 2 * 4096 * 7 / 8


def test_contributions_sorted():
    def step(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), 0
        return jax.lax.scan(body, x, w)[0]

    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    rows, full = H.contributions(_compile(step, w, x), top=5)
    assert rows and rows[0]["bytes"] >= rows[-1]["bytes"]
