"""Quickstart: train a Tsetlin Machine and classify — the paper's 'hello
world' (MNIST-shaped synthetic data, since the container is offline).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tm, train
from repro.data import paper_dataset


def main() -> None:
    X, y, Xte, yte = paper_dataset("mnist", n_train=3000, n_test=500)

    config = tm.TMConfig(
        n_features=784, n_classes=10, clauses_per_class=40,
        threshold=40, s=8.0,
    )
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(
        config, state, jnp.asarray(X), jnp.asarray(y),
        epochs=6, batch_size=50, rng=jax.random.PRNGKey(1),
        x_val=jnp.asarray(Xte), y_val=jnp.asarray(yte), log_every=2,
    )

    acc = float(tm.accuracy(config, state, jnp.asarray(Xte), jnp.asarray(yte)))
    include_frac = float((np.asarray(state.ta_state) >= 0).mean())
    print(f"\ntest accuracy: {acc:.3f}")
    print(f"include fraction: {include_frac:.3%}  <- the sparsity the paper "
          "exploits for boolean-to-silicon compilation")


if __name__ == "__main__":
    main()
