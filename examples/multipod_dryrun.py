"""Drive the multi-pod dry-run programmatically (deliverable e).

    PYTHONPATH=src python examples/multipod_dryrun.py --arch tinyllama-1.1b

Lowers + compiles the chosen architecture on both production meshes and
prints the memory/cost/roofline summary.
"""

# The dry-run module sets XLA_FLAGS before any jax import — import it first.
import repro.launch.dryrun as dryrun  # noqa: E402  (device-count side effect)

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    for mesh in ("pod", "multipod"):
        rec = dryrun.run_cell(args.arch, args.shape, mesh)
        print(f"\n== {args.arch} x {args.shape} on {mesh} "
              f"({rec['n_devices']} chips) ==")
        print(json.dumps({k: rec[k] for k in (
            "bottleneck", "t_comp", "t_mem", "t_coll",
            "useful_flops_ratio", "arg_bytes", "temp_bytes",
        )}, indent=2))


if __name__ == "__main__":
    main()
