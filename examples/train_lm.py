"""End-to-end LM training driver on the shared substrate: a ~100M-class model
for a few hundred steps with checkpointing + fault-tolerance wiring.

    PYTHONPATH=src python examples/train_lm.py --steps 200

(--steps 20 finishes in a couple of minutes on CPU; the default matches the
assignment's 'few hundred steps'.)
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models import steps, transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import StragglerMonitor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~25M-param llama-family model (CPU-trainable stand-in for the 100M run;
    # scale d_model/n_layers up on real hardware — same code path)
    cfg = ModelConfig(
        name="train-lm-demo", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=704, vocab_size=32000, dtype="float32",
    )
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps)
    step_fn = jax.jit(steps.make_train_step(cfg, opt_cfg=opt_cfg))

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "lm_demo_ckpt")
    mgr = CheckpointManager(ckpt_dir, max_to_keep=2)
    mon = StragglerMonitor()

    # synthetic structured data: next-token = (token * 31 + 7) % vocab with
    # noise — learnable, so the loss visibly drops
    nprng = np.random.default_rng(0)

    def make_batch():
        t0 = nprng.integers(0, cfg.vocab_size, (args.batch_size, 1))
        seq = [t0]
        for _ in range(args.seq_len):
            seq.append((seq[-1] * 31 + 7) % cfg.vocab_size)
        toks = np.concatenate(seq, axis=1)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    for step in range(args.steps):
        mon.start_step()
        params, opt, info = step_fn(params, opt, make_batch())
        mon.end_step(step)
        if (step + 1) % 10 == 0:
            print(f"step {step + 1:4d}: loss={float(info['loss']):7.4f} "
                  f"lr={float(info['lr']):.2e} gnorm={float(info['grad_norm']):.2f}")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"params": params}, extra={"step": step + 1},
                     blocking=False)
    mgr.wait()
    print(f"checkpoints in {ckpt_dir}: latest step {mgr.latest_step()}")


if __name__ == "__main__":
    main()
