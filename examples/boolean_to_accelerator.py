"""The full MATADOR flow (paper Fig. 6): train -> boolean-to-silicon compile
-> auto-verify -> deploy artifact -> throughput report.

    PYTHONPATH=src python examples/boolean_to_accelerator.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, packetizer, tm, train
from repro.data import paper_dataset


def main() -> None:
    # 1. train (the GUI's "Train" stage)
    X, y, Xte, yte = paper_dataset("mnist", n_train=3000, n_test=1000)
    config = tm.TMConfig(n_features=784, n_classes=10, clauses_per_class=40,
                         threshold=40, s=8.0)
    state = tm.init(config, jax.random.PRNGKey(0))
    state = train.fit(config, state, jnp.asarray(X), jnp.asarray(y),
                      epochs=6, batch_size=50, rng=jax.random.PRNGKey(1))

    # 2. boolean-to-silicon: compile the automata into the compact datapath
    compiled = compiler.compile_tm(config, state.ta_state)
    s = compiled.stats
    print("== design generation report (paper Fig. 8 analog) ==")
    print(f"  include sparsity     : {s.include_sparsity:.2%}")
    print(f"  clauses dense->unique: {s.n_clauses_dense} -> {s.n_clauses_unique} "
          f"(sharing {s.clause_sharing:.2%})")
    print(f"  words dense->active  : {s.n_words_dense} -> {s.n_words_active} "
          f"(compaction {s.word_compaction:.2%})")
    print(f"  partial AND terms    : {s.n_partial_terms_dense} -> "
          f"{s.n_partial_terms_unique} (sub-clause sharing "
          f"{s.partial_term_sharing:.2%})")

    # 3. design verification (the auto-debug stage): compiled == dense model
    pred_dense = np.asarray(tm.predict(config, state, jnp.asarray(Xte)))
    pred_comp = np.asarray(compiler.predict_compiled(compiled, jnp.asarray(Xte)))
    assert (pred_dense == pred_comp).all(), "verification FAILED"
    print("verification: compiled artifact == dense model on 1000 samples OK")

    # 3b. the same datapath through the fused Pallas kernel (interpret on CPU)
    pred_kernel = np.asarray(
        compiler.predict_compiled(compiled, jnp.asarray(Xte[:64]),
                                  engine="dense", interpret=True))
    assert (pred_kernel == pred_dense[:64]).all()
    print("verification: fused Pallas inference kernel path OK")

    # 4. deployment artifact
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "matador_accelerator.npz")
        compiled.save(path)
        size = os.path.getsize(path)
        reloaded = compiler.CompiledTM.load(path)
    print(f"deploy artifact: {size / 1024:.1f} KiB (fits on-chip — the paper's "
          "'no BRAM' point)")

    # 5. throughput (the jupyter-notebook stage)
    xp = packetizer.pack_literals(jnp.asarray(Xte))
    run = jax.jit(lambda xw: jnp.argmax(compiler.run_compiled(reloaded, xw), -1))
    run(xp).block_until_ready()
    t0 = time.perf_counter()
    out = run(xp)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    acc = float((np.asarray(out) == yte).mean())
    print(f"throughput: {len(yte) / dt:,.0f} inf/s "
          f"({dt / len(yte) * 1e6:.2f} us/inference), accuracy {acc:.3f}")


if __name__ == "__main__":
    main()
